"""Systematic crash-fault injection: CrashPlan machinery + layer sweeps.

The full checkpoint-layer sweep lives in tests/test_persistence.py next
to the other checkpoint durability tests; here we cover the injection
machinery itself, the serving-log layer exhaustively, and the (slower)
migration/rebalance layers on a bounded site budget — CI's dedicated
fault-injection lane runs tools/crash_sweep.py for the wider sweep.
"""
import numpy as np
import pytest

from repro.core.pmem import PMem, evicted_mask
from repro.persistence.manifest import StagedIO
from repro.robustness.faultinject import (CrashPlan, CrashPoint, SCENARIOS,
                                          _budget_indices, enumerate_sites,
                                          sweep)


# --------------------------------------------------------------------- #
# the unified eviction adversary (satellite: one policy, both models)    #
# --------------------------------------------------------------------- #
def test_evicted_mask_modes_and_determinism():
    rng = np.random.default_rng(0)
    assert not evicted_mask(4, "none", rng).any()
    assert evicted_mask(4, "all", rng).all()
    a = evicted_mask(64, "random", np.random.default_rng(7), 0.5)
    b = evicted_mask(64, "random", np.random.default_rng(7), 0.5)
    np.testing.assert_array_equal(a, b)          # seeded: replays exactly
    assert 0 < a.sum() < 64                      # genuinely mixed
    assert not evicted_mask(64, "random", np.random.default_rng(1), 0.0).any()
    assert evicted_mask(64, "random", np.random.default_rng(1), 1.0).all()


def test_unknown_evict_mode_raises_in_both_crash_models(tmp_path):
    with pytest.raises(ValueError, match="unknown evict mode"):
        evicted_mask(3, "sometimes", np.random.default_rng(0))
    mem = PMem(64)
    mem.write(8, 1)
    with pytest.raises(ValueError, match="unknown evict mode"):
        mem.crash(evict="sometimes")
    io = StagedIO(tmp_path)
    io.write("a", b"x")
    with pytest.raises(ValueError, match="unknown evict mode"):
        io.crash(evict="sometimes")


def test_stagedio_random_eviction_is_seeded(tmp_path):
    """Same seed, same staged set -> the same subset survives a crash."""
    def survivors(seed):
        io = StagedIO(tmp_path / f"s{seed}" / "x", seed=seed)
        for i in range(32):
            io.write(f"f{i:02d}", b"v")
        io.crash(evict="random", p_evict=0.5)
        return sorted(p.name for p in (tmp_path / f"s{seed}" / "x").glob(
            "f*"))
    assert survivors(3) == survivors(3)
    assert 0 < len(survivors(3)) < 32


# --------------------------------------------------------------------- #
# CrashPlan instrumentation                                              #
# --------------------------------------------------------------------- #
def test_pmem_sites_enumerated_and_crash_before(tmp_path):
    mem = PMem(64, line_words=8)
    plan = CrashPlan().attach(mem)
    mem.write(8, 1)
    mem.flush(8)
    mem.fence()
    mem.cas(16, 0, 5)
    assert [(s.kind, s.target) for s in plan.sites] == [
        ("flush", "line:1"), ("fence", ""), ("publish", "addr:16")]
    # crash-before: the fence (site 1) never executes, so the flushed
    # line is still pending at the crash and evict="none" drops it
    mem2 = PMem(64, line_words=8)
    plan2 = CrashPlan(crash_at=1).attach(mem2)
    mem2.write(8, 1)
    mem2.flush(8)
    with pytest.raises(CrashPoint) as ei:
        mem2.fence()
    assert ei.value.site.index == 1 and ei.value.site.kind == "fence"
    assert mem2.persistent[8] == 0               # pending write lost
    assert plan2.completed_sites() == plan2.sites[:1]
    # fired plan goes inert: recovery-path instructions are unobserved
    mem2.fence()
    assert len(plan2.sites) == 2


def test_stagedio_sites_and_whole_process_crash(tmp_path):
    """All attached objects crash together, and the publish site fires
    before the rename executes (the destination file never appears)."""
    io_a = StagedIO(tmp_path / "a")
    io_b = StagedIO(tmp_path / "b")
    plan = CrashPlan(crash_at=3, evict="none").attach(io_a, io_b)
    io_a.write("x.tmp", b"1")
    io_a.flush("x.tmp")                          # site 0
    io_b.write("y", b"2")
    io_b.flush("y")                              # site 1
    io_a.fence()                                 # site 2: x.tmp durable
    with pytest.raises(CrashPoint):
        io_a.publish("x.tmp", "x")               # site 3: never executes
    assert (tmp_path / "a" / "x.tmp").exists()
    assert not (tmp_path / "a" / "x").exists()   # publish did not happen
    assert not (tmp_path / "b" / "y").exists()   # b's staging lost too
    kinds = [s.kind for s in plan.sites]
    assert kinds == ["flush", "flush", "fence", "publish"]


def test_fuzz_mode_is_seed_deterministic(tmp_path):
    """p_crash fuzzing with the same seed fires at the same site."""
    def fired(seed):
        io = StagedIO(tmp_path / f"f{seed}" / "x")
        plan = CrashPlan(p_crash=0.12, seed=seed).attach(io)
        try:
            for i in range(40):
                io.write(f"g{i}", b"v")
                io.flush(f"g{i}")
                io.fence()
        except CrashPoint as cp:
            return cp.site.index
        return None
    assert fired(5) == fired(5)
    assert fired(5) is not None                  # 80 coins at p=0.12
    seeds = {fired(s) for s in range(6)}
    assert len(seeds) > 1                        # seeds actually vary


def test_budget_indices_cover_first_and_last():
    assert _budget_indices(5, None) == [0, 1, 2, 3, 4]
    assert _budget_indices(5, 99) == [0, 1, 2, 3, 4]
    for n, budget in ((29, 8), (100, 3), (7, 2)):
        idxs = _budget_indices(n, budget)
        assert idxs[0] == 0 and idxs[-1] == n - 1
        assert len(idxs) <= max(2, budget)
        assert idxs == sorted(set(idxs))


# --------------------------------------------------------------------- #
# layer sweeps                                                           #
# --------------------------------------------------------------------- #
def test_site_enumeration_is_deterministic():
    a = enumerate_sites(SCENARIOS["log"])
    b = enumerate_sites(SCENARIOS["log"])
    assert a == b
    assert len(a) > 20                           # commits+snapshots+trims
    assert {s.kind for s in a} >= {"flush", "fence", "publish", "trim"}


def test_request_log_sweep_every_site():
    """Crash at EVERY site of the serving-log scenario, both eviction
    modes: no acked op lost, oracle equivalence, took_effect answers."""
    rep = sweep(SCENARIOS["log"], evict_modes=("none", "random"))
    assert rep["failures"] == []
    assert rep["runs"] == 2 * rep["n_sites"]


def test_concurrent_log_sweep_every_site():
    """Two live RequestLogs on one dir, interleaved commits, crash at
    EVERY site: single-log invariants hold, and both fresh recoveries'
    metrics (records_parsed shim + registry counter) match the durable
    post-horizon record suffix each restart actually replayed."""
    rep = sweep(SCENARIOS["log2"], evict_modes=("none", "random"))
    assert rep["failures"] == []
    assert rep["runs"] == 2 * rep["n_sites"]
    assert rep["n_sites"] > 20


def test_migrate_sweep_budgeted():
    rep = sweep(SCENARIOS["migrate"], budget=8)
    assert rep["failures"] == []
    assert rep["n_sites"] > 15                   # the journal is covered


def test_rebalance_sweep_budgeted():
    rep = sweep(SCENARIOS["rebalance"], budget=8)
    assert rep["failures"] == []
    assert rep["n_sites"] > 15


# --------------------------------------------------------------------- #
# torn-payload (partial-write) adversary                                 #
# --------------------------------------------------------------------- #
def test_torn_payload_is_seeded_and_never_equal():
    """A torn image is a strict prefix plus (optionally) a garbled
    tail — deterministic under the seed and never byte-identical to
    the original for non-empty payloads."""
    from repro.persistence.manifest import _torn_payload
    data = bytes(range(64)) * 4
    a = _torn_payload(data, np.random.default_rng(5))
    b = _torn_payload(data, np.random.default_rng(5))
    assert a == b                              # seeded: replays exactly
    for seed in range(32):
        t = _torn_payload(data, np.random.default_rng(seed))
        assert t != data
        assert len(t) <= len(data)
        cut = len(t) if len(t) < len(data) else next(
            i for i, (x, y) in enumerate(zip(t, data)) if x != y)
        assert t[:cut] == data[:cut]           # strict common prefix
        if len(t) == len(data):                # garbled tail: inverted
            assert t[cut:] == bytes(255 - c for c in data[cut:])
    assert _torn_payload(b"", np.random.default_rng(0)) == b""


def test_stagedio_torn_crash_leaves_partial_files(tmp_path):
    """``crash(evict="torn")`` tears the staged-but-unfenced files in
    place instead of dropping them — the partial-write adversary."""
    io = StagedIO(tmp_path, seed=3)
    originals = {}
    for i in range(8):
        p = tmp_path / f"f_{i}.json"
        data = (b'{"k": %d}' % i) * 6
        originals[p] = data
        io.write(p, data)
        io.flush(p)
    io.crash(evict="torn")
    torn = survived = 0
    for p, data in originals.items():
        if not p.exists():
            continue
        got = p.read_bytes()
        if got == data:
            survived += 1
        else:
            torn += 1
            n = min(len(got), len(data))
            diff = next((i for i in range(n) if got[i] != data[i]), n)
            assert got[:diff] == data[:diff]   # torn, not rewritten
    assert torn > 0                            # adversary actually tore


def test_request_log_sweep_torn_mode():
    """Crash at every serving-log site with torn payloads: recovery
    must treat a partial record file (truncated or garbled, possibly
    invalid UTF-8) exactly like a torn record."""
    rep = sweep(SCENARIOS["log"], evict_modes=("torn",))
    assert rep["failures"] == []
    assert rep["runs"] == rep["n_sites"]


def test_checkpoint_and_migrate_sweep_torn_budgeted():
    for layer in ("checkpoint", "migrate"):
        rep = sweep(SCENARIOS[layer], budget=6, evict_modes=("torn",))
        assert rep["failures"] == [], layer


# --------------------------------------------------------------------- #
# sharded serving path (ROADMAP open item)                               #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("layer", ["log", "log2"])
def test_sharded_log_sweep_budgeted(layer):
    """log/log2 with the dedup index on the 2-shard durable-map
    backend: same no-acked-op-lost / prefix-durability / oracle-
    equivalence invariants, shard-count-independent."""
    import jax
    if jax.device_count() < 2:
        pytest.skip("needs 2 devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count=2)")
    rep = sweep(SCENARIOS[layer], budget=6,
                evict_modes=("none", "random", "torn"),
                scenario_kw={"shards": 2})
    assert rep["failures"] == []
    assert rep["runs"] == 3 * len(rep["tested_sites"])
