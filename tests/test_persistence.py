"""Checkpoint/recovery layer tests: the paper's protocol at framework
scale (delta commit, single fence, disconnect-style recovery)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.persistence.checkpoint import CheckpointManager
from repro.persistence.manifest import Manifest, manifest_rel


def _tree(step):
    return {"params": {"w": jnp.full((4, 4), float(step)),
                       "b": jnp.zeros((4,))},
            "opt": {"mu": jnp.full((4, 4), step * 0.1)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree(1), aux={"cursor": 7})
    man, tree = CheckpointManager(tmp_path).restore(_tree(0))
    assert man.step == 1 and man.aux["cursor"] == 7
    np.testing.assert_array_equal(np.asarray(tree["params"]["w"]),
                                  np.full((4, 4), 1.0))


def test_delta_checkpointing_skips_unchanged_leaves(tmp_path):
    """makePersistent at framework scale: unchanged shards are referenced,
    not rewritten."""
    mgr = CheckpointManager(tmp_path)
    t1 = _tree(1)
    mgr.save(1, t1)
    staged_before = mgr.io.counters.bytes_staged
    t2 = {"params": {"w": t1["params"]["w"] + 1,      # changed
                     "b": t1["params"]["b"]},         # unchanged
          "opt": t1["opt"]}                           # unchanged
    man = mgr.save(2, t2)
    assert man.files["params/b"]["owner"] == 1        # referenced
    assert man.files["opt/mu"]["owner"] == 1
    assert man.files["params/w"]["owner"] == 2        # rewritten
    # only w + manifest were staged
    new_bytes = mgr.io.counters.bytes_staged - staged_before
    assert new_bytes < 2 * t1["params"]["w"].nbytes + 4096


def test_single_fence_per_commit_vs_izraelevitz(tmp_path):
    big = {"p": {f"l{i}": jnp.ones((8, 8)) * i for i in range(20)}}
    nv = CheckpointManager(tmp_path / "nv", policy="nvtraverse")
    nv.save(1, big)
    iz = CheckpointManager(tmp_path / "iz", policy="izraelevitz")
    iz.save(1, big)
    assert nv.io.counters.fences == 1                 # THE fence
    assert iz.io.counters.fences >= 20                # fence per write
    # both recover identically
    for mgr_dir in ("nv", "iz"):
        man, tree = CheckpointManager(tmp_path / mgr_dir).restore(big)
        assert man.step == 1


@pytest.mark.parametrize("crash_phase", ["shards", "manifest"])
def test_crash_mid_commit_is_all_or_nothing(tmp_path, crash_phase):
    """An interrupted commit leaves no trace after recovery (the pending
    op is all-or-nothing) and the previous committed step survives."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree(1), aux={"ok": 1})
    out = mgr.save(2, _tree(2), crash_after=crash_phase)
    assert out is None
    mgr.io.crash(evict="none")
    man = CheckpointManager(tmp_path).recover()
    assert man is not None and man.step == 1
    man2, tree = CheckpointManager(tmp_path).restore(_tree(0))
    np.testing.assert_array_equal(np.asarray(tree["params"]["w"]),
                                  np.full((4, 4), 1.0))


def test_crash_with_eviction_adversary(tmp_path):
    """Even if an arbitrary subset of staged files reached disk, an
    unpublished commit must not resurrect (the publish rename is the
    linearization point)."""
    for seed in range(5):
        root = tmp_path / f"s{seed}"
        mgr = CheckpointManager(root, seed=seed)
        mgr.save(1, _tree(1))
        mgr.save(2, _tree(2), crash_after="manifest")
        mgr.io.crash(evict="random", p_evict=0.7)
        man = CheckpointManager(root).recover()
        assert man.step == 1


def test_recovery_trims_corrupt_manifest_chain(tmp_path):
    """A committed manifest whose referenced shard is corrupt is trimmed
    (dependency-closedness), falling back to the previous valid step."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    # corrupt step 2's shard on disk
    man2 = Manifest.from_bytes(mgr.io.read(manifest_rel(2)))
    victim = man2.files["params/w"]["file"]
    (mgr.io.root / victim).write_bytes(b"garbage")
    man = CheckpointManager(tmp_path).recover()
    assert man.step == 1


def test_recovery_trims_stray_out_of_range_step_dir(tmp_path):
    """A stray step_* directory whose number is outside the durable
    map's int32 key space must be trimmed by recovery — not crash the
    membership probe."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree(1))
    stray = tmp_path / f"step_{2**40:08d}"
    stray.mkdir()
    (stray / "junk.npy").write_bytes(b"junk")
    man = CheckpointManager(tmp_path).recover()
    assert man.step == 1
    assert not stray.exists()


def test_gc_keeps_delta_references_alive(tmp_path):
    mgr = CheckpointManager(tmp_path)
    t = _tree(1)
    mgr.save(1, t)
    for s in (2, 3, 4):
        t = {"params": {"w": t["params"]["w"] + 1,
                        "b": t["params"]["b"]},       # never changes
             "opt": t["opt"]}
        mgr.save(s, t)
    mgr.gc(keep=2)
    man, tree = CheckpointManager(tmp_path).restore(t)
    assert man.step == 4
    np.testing.assert_array_equal(np.asarray(tree["params"]["b"]),
                                  np.zeros((4,)))     # ref to step1 survives


def test_gc_trims_dead_steps_from_live_index(tmp_path):
    """recover()/gc() keep one live-step MembershipIndex current across
    passes — dead steps leave by a mixed insert/delete round instead of
    the index being rebuilt — and the probe matches what is on disk."""
    mgr = CheckpointManager(tmp_path)
    # fully-changing trees: no delta references, old steps really die
    for s in (1, 2, 3, 4):
        mgr.save(s, {"w": jnp.full((4,), float(s))})
    mgr.recover()
    assert list(mgr._step_index.contains([1, 2, 3, 4])) == [True] * 4
    mgr.gc(keep=2)
    assert list(mgr._step_index.contains([1, 2, 3, 4])) == \
        [False, False, True, True]
    assert not (tmp_path / "step_00000001").exists()
    assert (tmp_path / "step_00000004").exists()
    # a later pass re-adds nothing and the survivors stay probe-able
    man = mgr.recover()
    assert man.step == 4
    assert list(mgr._step_index.contains([3, 4])) == [True, True]


def test_checkpoint_crash_at_every_site():
    """Systematic generalization of the hand-picked crash_after hooks
    above: crash at EVERY flush/fence/publish/trim site of a save+gc
    chain (both eviction adversaries) and require recovery to land on
    exactly the last acked step with its exact tree — the
    repro.robustness.faultinject sweep as a persistence-layer test."""
    from repro.robustness.faultinject import SCENARIOS, sweep
    rep = sweep(SCENARIOS["checkpoint"], evict_modes=("none", "random"))
    assert rep["failures"] == []
    kinds = {s["kind"] for s in rep["sites"]}
    # the chain really exercises every instruction class, gc trim
    # included (step 1 dies at gc time in the scenario)
    assert kinds == {"flush", "fence", "publish", "trim"}
    assert rep["runs"] == 2 * rep["n_sites"]


def test_mesh_agnostic_restore(tmp_path):
    """Manifests are layout-free: restore onto a different sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(tmp_path)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, tree)
    mesh = jax.make_mesh((1,), ("model",))
    sh = {"w": NamedSharding(mesh, P(None, None))}
    man, restored = CheckpointManager(tmp_path).restore(tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(16.0).reshape(4, 4))
