"""Online migration engine (core/migrate.py).

The contract under test: migration is a sequence of bounded
NVTraverse-correct rounds — bit-identical to an oracle build where it
can be (pure migration), content-identical under live traffic, and
crash-recoverable to exactly a round boundary (pre-round or post-round,
never a torn mix) at *every* frontier position.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import batched as B
from repro.core.migrate import (MigratingMap, MigrationState, drain_range,
                                host_state, migrate_state)

NB = 16


def assert_states_equal(a, b, ctx=""):
    for f in a._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{ctx}: field {f} diverged")


def seeded_state(n=150, nb=NB, cap=512, deletes=True):
    st = B.make_state(cap, nb)
    ks = jnp.arange(1, n + 1)
    st, _, _ = B.insert_parallel(st, ks, ks * 3, nb)
    if deletes:     # dead nodes mid-chain: the drain must skip them
        st, _, _ = B.delete_parallel(st, ks[::5], nb)
        st, _, _ = B.insert_parallel(st, ks[::10], ks[::10] * 7, nb)
    return st


def test_pure_migration_bit_identical_to_oracle_build():
    """A quiescent migration is a fresh build: replaying the drained
    (bucket-order, chain-order) sequence through the sequential oracle
    must reproduce the migrated table bit for bit — state arrays AND
    flush/fence accounting."""
    st = seeded_state()
    for bpr in (1, 3, NB):       # round size must not matter
        new, rep = migrate_state(st, NB, 1024, 32, buckets_per_round=bpr)
        ks, vs = drain_range(host_state(st), 0, NB)
        oracle, ok = B.insert(B.make_state(1024, 32), jnp.asarray(ks),
                              jnp.asarray(vs), 32)
        assert bool(ok.all())
        assert_states_equal(new, oracle, f"bpr={bpr}")
        assert rep.migrated == ks.size
        assert rep.rounds == -(-NB // bpr)


def test_migrate_state_drops_dead_nodes_and_rehashes():
    st = seeded_state()
    live_before = int(np.asarray(st.live).sum())
    new, rep = migrate_state(st, NB, 1024, 64)
    assert int(new.cursor) == 1 + live_before      # compacted
    mx_old, mean_old = B.chain_stats(st, NB)
    mx_new, mean_new = B.chain_stats(new, 64)
    assert float(mean_new) < float(mean_old)       # rehash spread chains
    # content identical
    f_old, v_old = B.lookup(st, jnp.arange(1, 200), NB)
    f_new, v_new = B.lookup(new, jnp.arange(1, 200), 64)
    np.testing.assert_array_equal(np.asarray(f_old), np.asarray(f_new))
    np.testing.assert_array_equal(np.asarray(v_old), np.asarray(v_new))


def test_migrate_state_overflow_raises():
    st = seeded_state(deletes=False)
    with pytest.raises(RuntimeError):
        migrate_state(st, NB, 64, 32)              # 150 live keys, pool 64


def test_lookup_during_migration_new_then_old():
    """At every frontier position, lookups answer from the merged view;
    a key deleted (or re-inserted) during migration is owned by the new
    table even though the old table still holds its stale copy."""
    m = MigratingMap(capacity=256, n_buckets=NB)
    ks = np.arange(1, 101, dtype=np.int32)
    m.insert(ks, ks * 3)
    m.start_migration(buckets_per_round=1)
    # user traffic against un-migrated keys: delete 7, overwrite 9
    assert list(m.delete(np.array([7], np.int32))) == [True]
    assert list(m.delete(np.array([9], np.int32))) == [True]
    assert list(m.insert(np.array([9], np.int32),
                         np.array([999], np.int32))) == [True]
    model = {int(k): int(k) * 3 for k in ks}
    del model[7]
    model[9] = 999
    while m.migrating:
        f, v = m.lookup(ks)
        for k, ff, vv in zip(ks, f, v):
            assert bool(ff) == (int(k) in model), (m.frontier, k)
            if ff:
                assert int(vv) == model[int(k)], (m.frontier, k)
        m.migrate_round()
    # after the swap the stale old copies of 7/9 are gone for good
    f, v = m.lookup(np.array([7, 9], np.int32))
    assert list(f) == [False, True] and int(v[1]) == 999
    live = {k: v for k, (l, v) in m.items().items() if l}
    assert live == model


def test_dead_in_new_vetoes_live_in_old():
    """The new-authoritative rule specifically: a key whose only new-
    table node is DEAD (deleted during migration) must not be
    resurrected by its old live copy — neither by lookups nor by the
    drain of its bucket."""
    m = MigratingMap(capacity=256, n_buckets=NB)
    ks = np.arange(1, 51, dtype=np.int32)
    m.insert(ks, ks * 3)
    m.start_migration(buckets_per_round=1)
    m.delete(ks)                     # kill everything mid-migration
    f, _ = m.lookup(ks)
    assert not f.any()
    while m.migrating:               # drains must all be filtered out
        m.migrate_round()
    f, _ = m.lookup(ks)
    assert not f.any()
    assert all(not l for l, _ in m.items().values())


def test_growth_is_invisible_to_op_results():
    """ok flags across a growth event equal a single big-pool engine run
    (growth never fails an op that would fit an unbounded pool)."""
    rng = np.random.default_rng(2)
    m = MigratingMap(capacity=32, n_buckets=8, rounds_per_update=1)
    big = B.make_state(1 << 14, 8)
    for rnd in range(25):
        n = int(rng.integers(8, 48))
        ops = rng.integers(0, 2, size=n).astype(np.int32)
        ks = rng.integers(0, 300, size=n).astype(np.int32)
        vs = rng.integers(0, 1000, size=n).astype(np.int32)
        ok = m.update(ops, ks, vs)
        big, ok_big, _ = B.update_parallel(
            big, jnp.asarray(ops), jnp.asarray(ks), jnp.asarray(vs), 8)
        np.testing.assert_array_equal(ok, np.asarray(ok_big),
                                      err_msg=f"round {rnd}")
    assert m.migrations_completed >= 1
    from repro.core.sharded import items_of_state
    live_big = {k: v for k, (l, v) in items_of_state(big).items() if l}
    live_m = {k: v for k, (l, v) in m.items().items() if l}
    assert live_m == live_big


# --------------------------------------------------------------------- #
# crash recovery                                                         #
# --------------------------------------------------------------------- #
def _run_to_crash(root, crash_after_rounds, seed_n=40):
    """Seed, start a migration, crash after exactly N rounds; returns
    the reference (new-table state, frontier) at each boundary."""
    m = MigratingMap(capacity=128, n_buckets=8, root=root,
                     buckets_per_round=1)
    ks = np.arange(1, seed_n + 1, dtype=np.int32)
    m.insert(ks, ks * 5)
    m.delete(ks[::4])
    m.start_migration()
    r = 0
    while m.migrating:
        if r == crash_after_rounds:
            m.crash()
            return None
        m.migrate_round()
        r += 1
    m.crash()
    return m


def _reference_boundaries(tmp_path, seed_n=40):
    m = MigratingMap(capacity=128, n_buckets=8,
                     root=tmp_path / "ref", buckets_per_round=1)
    ks = np.arange(1, seed_n + 1, dtype=np.int32)
    m.insert(ks, ks * 5)
    m.delete(ks[::4])
    m.start_migration()
    bounds = []
    while m.migrating:
        bounds.append((m.frontier, m._mig["new"]))
        m.migrate_round()
    bounds.append((8, m.state))
    return bounds


@pytest.mark.parametrize("crash_round", list(range(9)))
def test_crash_replay_every_frontier(tmp_path, crash_round):
    """Kill the process between migration rounds at every frontier
    position: recovery must land bit-identical on a round boundary —
    the journal's last published round — never a torn mix, and
    resuming from the recovered frontier must finish to the same final
    table as the uninterrupted run."""
    bounds = _reference_boundaries(tmp_path)
    n_rounds = len(bounds) - 1                      # 8 drain rounds
    root = tmp_path / f"crash{crash_round}"
    if crash_round < n_rounds:
        _run_to_crash(root, crash_round)
        rec = MigratingMap.recover(root)
        assert rec.migrating and rec.frontier == bounds[crash_round][0]
        assert_states_equal(rec._mig["new"], bounds[crash_round][1],
                            f"recovered new table, round {crash_round}")
        rec.run_migration()
    else:                                           # crash after DONE
        _run_to_crash(root, crash_round)
        rec = MigratingMap.recover(root)
        assert not rec.migrating
    assert_states_equal(rec.state, bounds[-1][1],
                        f"final state via crash at {crash_round}")


def test_crash_with_user_rounds_replays_mixed_journal(tmp_path):
    """User traffic during migration is journaled too: recovery replays
    the interleaved drain + pull/user rounds and lands on the exact
    merged state."""
    m = MigratingMap(capacity=128, n_buckets=8, root=tmp_path,
                     buckets_per_round=2)
    ks = np.arange(1, 41, dtype=np.int32)
    m.insert(ks, ks * 5)
    m.start_migration()
    m.migrate_round()
    m.delete(np.array([1, 2, 3], np.int32))
    m.insert(np.array([100, 2], np.int32), np.array([7, 8], np.int32))
    ref_new = m._mig["new"]
    ref_frontier = m.frontier
    m.crash()
    rec = MigratingMap.recover(tmp_path)
    assert rec.migrating and rec.frontier == ref_frontier
    assert_states_equal(rec._mig["new"], ref_new, "mixed journal")
    rec.run_migration()
    live = {k: v for k, (l, v) in rec.items().items() if l}
    assert live[100] == 7 and live[2] == 8 and 1 not in live


def test_unfenced_round_is_lost_fenced_round_survives(tmp_path):
    """The journal commit point is the atomic publish: a crash that
    loses the staging area rolls back exactly to the last published
    round."""
    m = MigratingMap(capacity=128, n_buckets=8, root=tmp_path,
                     buckets_per_round=1)
    m.insert(np.arange(1, 31, dtype=np.int32),
             np.arange(1, 31, dtype=np.int32))
    m.start_migration()
    m.migrate_round()
    pre = m._mig["new"]
    # hand-stage round bytes without fencing/publishing = mid-round crash
    m.io.write("mig_0001/round.tmp", b"torn")
    m.crash()
    rec = MigratingMap.recover(tmp_path)
    assert rec.frontier == 1
    assert_states_equal(rec._mig["new"], pre, "unfenced round leaked")


def test_migration_state_header_roundtrip():
    h = MigrationState(phase="migrating", frontier=3, old=(128, 8),
                       new=(512, 16), buckets_per_round=2, n_rounds=5)
    assert MigrationState.from_bytes(h.to_bytes()) == h


@pytest.mark.slow
def test_acceptance_8c_growth_under_live_mixed_traffic():
    """Acceptance criterion (single-device half): a map seeded at
    capacity C absorbs 8C inserts under live mixed traffic via
    migration rounds; the final state is content-identical to an oracle
    of the same live set, and replaying the stream through a fresh
    big-pool engine agrees op for op."""
    C = 1024
    rng = np.random.default_rng(11)
    m = MigratingMap(capacity=C, n_buckets=64, rounds_per_update=2)
    model = {}
    next_key = 1
    inserted = 0
    while inserted < 8 * C:
        n_ins, n_upd = 192, 64
        ks_ins = np.arange(next_key, next_key + n_ins, dtype=np.int32)
        next_key += n_ins
        inserted += n_ins
        ks_upd = rng.integers(1, next_key, size=n_upd).astype(np.int32)
        ops = np.concatenate([np.zeros(n_ins, np.int32),
                              rng.integers(0, 2, n_upd).astype(np.int32)])
        ks = np.concatenate([ks_ins, ks_upd])
        vs = (ks * 3 + 1).astype(np.int32)
        ok = m.update(ops, ks, vs)
        for o, k, v, okk in zip(ops, ks, vs, ok):
            k = int(k)
            if o == B.OP_INSERT:
                assert bool(okk) == (k not in model)
                if okk:
                    model[k] = int(v)
            else:
                assert bool(okk) == (k in model)
                model.pop(k, None)
    assert m.migrations_completed >= 3          # 8x growth, 2x per step
    assert m.capacity >= 8 * C
    items = m.items()
    live = {k: v for k, (l, v) in items.items() if l}
    assert live == model
    # the final table also answers a full scan correctly
    probe = np.arange(1, next_key, dtype=np.int32)
    f, v = m.lookup(probe)
    np.testing.assert_array_equal(
        f, np.asarray([int(k) in model for k in probe]))


# --------------------------------------------------------------------- #
# hypothesis: interleaved user ops + migration rounds                    #
# --------------------------------------------------------------------- #
def _interleaved_body(events):
    """Any interleaving of user batches, explicit migration starts, and
    migration rounds observes dict semantics at every step."""
    m = MigratingMap(capacity=16, n_buckets=4, rounds_per_update=1,
                     buckets_per_round=1)
    model = {}
    for kind, k, v in events:
        if kind == "start" and not m.migrating:
            m.start_migration()
        elif kind == "round" and m.migrating:
            m.migrate_round()
        elif kind == "ins":
            ok = m.insert(np.array([k], np.int32),
                          np.array([v], np.int32))
            assert bool(ok[0]) == (k not in model)
            if ok[0]:
                model[k] = v
        elif kind == "del":
            ok = m.delete(np.array([k], np.int32))
            assert bool(ok[0]) == (k in model)
            model.pop(k, None)
        f, vals = m.lookup(np.arange(40, dtype=np.int32))
        for kk in range(40):
            assert bool(f[kk]) == (kk in model)
            if f[kk]:
                assert int(vals[kk]) == model[kk]
    live = {k: v for k, (l, v) in m.items().items() if l}
    assert live == model


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.lists(
        st.tuples(st.sampled_from(["ins", "del", "round", "start"]),
                  st.integers(0, 39), st.integers(0, 99)),
        min_size=1, max_size=40))
    def test_interleaved_ops_and_rounds_match_dict_model(events):
        _interleaved_body(events)
except ImportError:      # hypothesis optional: keep a fixed-trace probe
    def test_interleaved_ops_and_rounds_match_dict_model():
        rng = np.random.default_rng(4)
        kinds = ["ins", "del", "round", "start"]
        events = [(kinds[int(rng.integers(0, 4))],
                   int(rng.integers(0, 40)), int(rng.integers(0, 100)))
                  for _ in range(40)]
        _interleaved_body(events)
