"""Live cross-shard rebalancing (core/rebalance.py).

The contract under test: a rebalance interleaved with concurrent mixed
user batches yields per-key state identical to the blocking
``ShardedDurableMap.rebalance`` followed by the same batches (and to a
dict oracle), with zero foreign ops and owner-range-only flushes after
completion; a crash at *any* round boundary recovers bit-identically to
that boundary and resumes; and skewed streams trigger boundary
re-splits by themselves via :class:`AutoRebalancePolicy`.

Single-shard tests run everywhere (a 1-device mesh exercises the full
drain/route/pull/journal pipeline); multi-shard tests skip unless
enough jax devices exist — CI runs them in the multi-device lane under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import batched as B
from repro.core.rebalance import (AutoRebalancePolicy, RebalanceState,
                                  RebalancingShardedMap)
from repro.core.sharded import ShardedDurableMap

NB = 32


def _need(n):
    return pytest.mark.skipif(
        jax.device_count() < n,
        reason=f"needs {n} devices (XLA_FLAGS="
               f"--xla_force_host_platform_device_count={n})")


def _batch(rng, n, key_hi=200):
    return (rng.integers(0, 2, n).astype(np.int32),
            rng.integers(0, key_hi, n).astype(np.int32),
            rng.integers(0, 1000, n).astype(np.int32))


def _track(model, ops, ks, vs, ok):
    for o, k, v, okk in zip(ops, ks, vs, ok):
        if o == B.OP_INSERT and okk:
            model[int(k)] = int(v)
        elif o == B.OP_DELETE and okk:
            model.pop(int(k), None)


def _live(m):
    return {k: v for k, (l, v) in m.items().items() if l}


def _assert_sharded_states_equal(a, b, ctx=""):
    for f in a._fields:
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(getattr(a, f))),
            np.asarray(jax.device_get(getattr(b, f))),
            err_msg=f"{ctx}: field {f} diverged")


def _drive_equivalence(S, splits_new, seed, rounds=None):
    """Drive a live rebalance and the blocking-rebalance-then-batches
    reference through identical traffic; assert per-op ok, per-step
    lookups vs a dict oracle, and final per-key content all agree."""
    m = RebalancingShardedMap(S, capacity=2048, n_buckets=NB,
                              rounds_per_update=1)
    blk = ShardedDurableMap(S, capacity=2048, n_buckets=NB)
    rng = np.random.default_rng(seed)
    model = {}
    for _ in range(3):
        ops, ks, vs = _batch(rng, 60)
        ok1, _ = m.update(ops, ks, vs)
        ok2, _ = blk.update(ops, ks, vs)
        np.testing.assert_array_equal(ok1, ok2)
        _track(model, ops, ks, vs, ok1)
    blk.rebalance(splits_new, buckets_per_round=5)
    m.start_rebalance(splits_new, buckets_per_round=5)
    probe = np.arange(220, dtype=np.int32)
    while m.rebalancing:
        ops, ks, vs = _batch(rng, 40)
        ok1, _ = m.update(ops, ks, vs)          # advances drain rounds
        ok2, _ = blk.update(ops, ks, vs)
        np.testing.assert_array_equal(
            ok1, ok2, err_msg=f"frontier {m.frontier}: ok diverged")
        _track(model, ops, ks, vs, ok1)
        f, v = m.lookup(probe)
        for k in probe:
            assert bool(f[k]) == (int(k) in model), (m.frontier, int(k))
            if f[k]:
                assert int(v[k]) == model[int(k)], (m.frontier, int(k))
    assert m.splits == tuple(splits_new)
    assert m.last_report.foreign_ops == 0
    assert _live(m) == _live(blk) == model
    return m, blk, rng, model


def test_live_equivalence_single_shard():
    """Tier-1 guard: a 1-shard live rebalance runs the whole pipeline
    (frozen snapshot, bounded drains, pull-first user commits, merged
    lookups, adoption) and must match blocking-then-batches op for op."""
    m, _, _, _ = _drive_equivalence(1, (0, NB), seed=0)
    assert m.pulls_total > 0            # user traffic really did pull
    assert m.rebalances_completed == 1


def test_dead_in_new_vetoes_live_in_old():
    """A key deleted mid-rebalance must stay dead: its dead node in the
    new map vetoes the old map's stale live copy for lookups AND for
    every later drain of its bucket."""
    m = RebalancingShardedMap(1, capacity=1024, n_buckets=NB)
    ks = np.arange(1, 51, dtype=np.int32)
    m.insert(ks, ks * 3)
    m.start_rebalance((0, NB), buckets_per_round=1)
    m.delete(ks)                         # kill everything mid-rebalance
    f, _ = m.lookup(ks)
    assert not f.any()
    migrated_before = m.migrated_total   # (the delete call itself first
    while m.rebalancing:                 # advanced one drain round)
        m.rebalance_round()
    f, _ = m.lookup(ks)
    assert not f.any()
    assert all(not l for l, _ in m.items().values())
    # every post-delete drain was filtered out by the dead new nodes
    assert m.migrated_total == migrated_before


def test_quiescent_live_rebalance_matches_blocking_bit_for_bit():
    """With no user traffic interleaved, the live rebalance commits the
    exact same routed rounds as the blocking one — the adopted state
    arrays are bit-identical, not just content-equal."""
    def seeded(cls_kwargs=None):
        m = (RebalancingShardedMap if cls_kwargs is not None else
             ShardedDurableMap)(1, capacity=1024, n_buckets=NB,
                                **(cls_kwargs or {}))
        ks = np.arange(1, 201, dtype=np.int32)
        m.insert(ks, ks * 3)
        m.delete(ks[::3])
        return m
    live = seeded({})
    blk = seeded(None)
    live.start_rebalance((0, NB), buckets_per_round=5)
    live.run_rebalance()
    blk.rebalance((0, NB), buckets_per_round=5)
    _assert_sharded_states_equal(live.map.state, blk.state, "quiescent")
    assert live.last_report.migrated > 0
    assert live.last_report.foreign_ops == 0


def test_start_rebalance_rejects_in_flight_and_undersized():
    m = RebalancingShardedMap(1, capacity=256, n_buckets=NB)
    ks = np.arange(1, 101, dtype=np.int32)
    m.insert(ks, ks)
    m.start_rebalance((0, NB))
    with pytest.raises(RuntimeError):
        m.start_rebalance((0, NB))
    m.run_rebalance()
    with pytest.raises(ValueError):      # 100 live keys into a 64-pool
        m.start_rebalance((0, NB), capacity=64)


def test_rebalance_state_header_roundtrip():
    h = RebalanceState(phase="rebalancing", frontier=8, n_buckets=NB,
                       capacity_old=1024, capacity_new=2048,
                       splits_old=(0, 16, NB), splits_new=(0, 4, NB),
                       buckets_per_round=4, n_rounds=3)
    assert RebalanceState.from_bytes(h.to_bytes()) == h


# --------------------------------------------------------------------- #
# crash recovery                                                         #
# --------------------------------------------------------------------- #
BPR = 4                                  # 32 buckets / 4 = 8 drain rounds


def _seeded_live(root, S=1):
    m = RebalancingShardedMap(S, capacity=1024, n_buckets=NB, root=root)
    ks = np.arange(1, 121, dtype=np.int32)
    m.insert(ks, ks * 5)
    m.delete(ks[::4])
    return m


@pytest.fixture(scope="module")
def reference_boundaries(tmp_path_factory):
    """(frontier, new-map state) at every round boundary of an
    uninterrupted run, plus the final adopted state — computed once."""
    m = _seeded_live(tmp_path_factory.mktemp("ref") / "j")
    m.start_rebalance((0, NB), buckets_per_round=BPR)
    bounds = []
    while m.rebalancing:
        bounds.append((m.frontier, jax.device_get(m._reb["new"].state)))
        m.rebalance_round()
    bounds.append((NB, jax.device_get(m.map.state)))
    return bounds


@pytest.mark.parametrize("crash_round", list(range(NB // BPR + 1)))
def test_crash_replay_every_frontier(tmp_path, reference_boundaries,
                                     crash_round):
    """Kill the process between rebalance rounds at every frontier
    position: recovery must land bit-identical on a round boundary —
    the journal's last published round, never a torn mix — and
    resuming from the recovered frontier must finish to the same final
    map as the uninterrupted run."""
    bounds = reference_boundaries
    n_rounds = len(bounds) - 1
    m = _seeded_live(tmp_path)
    m.start_rebalance((0, NB), buckets_per_round=BPR)
    for _ in range(min(crash_round, n_rounds)):
        m.rebalance_round()
    m.crash()
    rec = RebalancingShardedMap.recover(tmp_path, 1)
    if crash_round < n_rounds:
        assert rec.rebalancing
        assert rec.frontier == bounds[crash_round][0]
        _assert_sharded_states_equal(
            rec._reb["new"].state, bounds[crash_round][1],
            f"recovered new map, round {crash_round}")
        rec.run_rebalance()
    else:                                # crash after DONE
        assert not rec.rebalancing
    _assert_sharded_states_equal(rec.map.state, bounds[-1][1],
                                 f"final state via crash {crash_round}")


def test_crash_with_user_rounds_replays_mixed_journal(tmp_path):
    """User traffic during a rebalance is journaled too: recovery
    replays the interleaved drain + [pull; user] rounds in publish
    order and lands on the exact merged state, then resumes."""
    m = _seeded_live(tmp_path)
    m.start_rebalance((0, NB), buckets_per_round=BPR)
    m.rebalance_round()
    ok, _ = m.delete(np.array([2, 3, 4], np.int32))   # live (not ::4)
    assert list(ok) == [True, True, True]
    ok, _ = m.insert(np.array([500, 2], np.int32),
                     np.array([7, 8], np.int32))
    assert list(ok) == [True, True]
    ref_new = jax.device_get(m._reb["new"].state)
    ref_frontier = m.frontier
    m.crash()
    rec = RebalancingShardedMap.recover(tmp_path, 1)
    assert rec.rebalancing and rec.frontier == ref_frontier
    _assert_sharded_states_equal(rec._reb["new"].state, ref_new,
                                 "mixed journal")
    rec.run_rebalance()
    live = _live(rec)
    assert live[500] == 7 and live[2] == 8
    assert 3 not in live and 4 not in live


def test_unfenced_round_is_lost_fenced_round_survives(tmp_path):
    """The journal commit point is the atomic publish: a crash that
    loses the staging area rolls back exactly to the last published
    round."""
    m = _seeded_live(tmp_path)
    m.start_rebalance((0, NB), buckets_per_round=BPR)
    m.rebalance_round()
    pre = jax.device_get(m._reb["new"].state)
    # hand-stage round bytes without fencing/publishing = mid-round crash
    m.io.write("reb_0001/round.tmp", b"torn")
    m.crash()
    rec = RebalancingShardedMap.recover(tmp_path, 1)
    assert rec.frontier == BPR
    _assert_sharded_states_equal(rec._reb["new"].state, pre,
                                 "unfenced round leaked")


# --------------------------------------------------------------------- #
# multi-shard: locality + the acceptance shapes                          #
# --------------------------------------------------------------------- #
@_need(2)
def test_live_equivalence_uneven_splits_multi_shard():
    """The acceptance-criteria shape: a live re-split onto uneven
    boundaries under mixed traffic matches blocking-then-batches and
    the dict oracle; after completion every flush of further traffic
    lands inside its (new) owner range with zero foreign ops."""
    S = 2 if jax.device_count() < 4 else 4
    splits = (0, 12, NB) if S == 2 else (0, 6, 12, 20, NB)
    m, blk, rng, model = _drive_equivalence(S, splits, seed=7)
    for _ in range(3):
        ops, ks, vs = _batch(rng, 60)
        ok1, stats = m.update(ops, ks, vs)
        ok2, _ = blk.update(ops, ks, vs)
        np.testing.assert_array_equal(ok1, ok2)
        _track(model, ops, ks, vs, ok1)
        assert int(np.sum(np.asarray(stats.foreign_ops))) == 0
        bf = np.asarray(stats.bucket_flushes)
        for s in range(S):
            lo, hi = splits[s], splits[s + 1]
            # shard s's flushes all land in its own (uneven) range
            assert int(np.asarray(stats.coalesced_flushes)[s]) == \
                int(bf[lo:hi].sum())
    assert _live(m) == model


@_need(2)
def test_auto_rebalance_triggers_on_skew():
    """The zipf-skew acceptance: traffic hammering keys owned by ONE
    shard must start (and complete) a re-split by itself, shrink the
    hot range, and keep answering like a dict throughout."""
    S = 2 if jax.device_count() < 4 else 4
    nb_local = NB // S
    hot = [k for k in range(4000)
           if int(B.bucket_of_np(np.asarray([k], np.int32), NB)[0])
           < nb_local][:40]
    assert len(hot) == 40
    m = RebalancingShardedMap(
        S, capacity=4096, n_buckets=NB, rounds_per_update=2,
        policy=AutoRebalancePolicy(threshold=1.3, min_load=64,
                                   check_every=2))
    rng = np.random.default_rng(3)
    model = {}
    for _ in range(24):
        ks = np.asarray(rng.choice(hot, 48), np.int32)
        ops = rng.integers(0, 2, 48).astype(np.int32)
        vs = rng.integers(0, 1000, 48).astype(np.int32)
        ok, _ = m.update(ops, ks, vs)
        _track(model, ops, ks, vs, ok)
    assert m.rebalances_completed >= 1
    assert m.last_trigger_imbalance > 1.3
    assert m.splits[1] <= nb_local       # the hot range shrank
    assert _live(m) == model
    f, v = m.lookup(np.asarray(hot, np.int32))
    for k, ff, vv in zip(hot, f, v):
        assert bool(ff) == (k in model)
        if ff:
            assert int(vv) == model[k]


@_need(2)
def test_index_and_requestlog_live_rebalance(tmp_path):
    """The consumers: a sharded MembershipIndex with auto_rebalance
    grows and re-splits without dropping members, and a RequestLog opts
    in end to end."""
    from repro.persistence.index import MembershipIndex
    from repro.serving.engine import RequestLog

    idx = MembershipIndex(capacity=64, n_buckets=128, n_shards=2,
                          auto_rebalance=True)
    keys = list(range(100, 400))
    for i in range(0, len(keys), 32):
        idx.add(keys[i:i + 32])
    assert idx.migrations >= 1           # grew through the live wrapper
    assert bool(idx.contains(keys).all())
    idx.update(add_keys=[500], remove_keys=keys[:50])
    assert not idx.contains(keys[:50]).any()
    assert bool(idx.contains([500])[0])
    assert idx.rebalances >= 0           # counter exists and is sane

    log = RequestLog(tmp_path, shards=2, rebalance=True)
    log.commit({1: [10], 2: [20]})
    log.commit({3: [30]}, evict=[1])
    assert list(log.is_committed([1, 2, 3])) == [False, True, True]
    assert log.dedup_rebalances == 0


def test_index_growth_mid_rebalance_counts_dead_in_old_keys():
    """Regression: a key whose only node is a DEAD one in the frozen
    old map still allocates a fresh node in the new map on re-insert —
    the index fits check must count it (the merged probe's ``exists``
    would wrongly exclude it), grow, and never drop members."""
    from repro.persistence.index import MembershipIndex

    idx = MembershipIndex(capacity=16, n_buckets=NB, n_shards=1,
                          auto_rebalance=True)
    keys = list(range(1, 9))
    idx.add(keys)
    idx.remove([1, 2])                   # dead nodes in the map
    idx._backend.map.start_rebalance((0, NB), buckets_per_round=2)
    # re-add the dead-in-old keys plus enough fresh ones to overflow a
    # 16-slot pool unless the fits check grows first
    idx.add([1, 2] + list(range(100, 108)))
    assert bool(idx.contains(keys[2:] + [1, 2]
                             + list(range(100, 108))).all())
    assert idx.migrations >= 1


def test_auto_trigger_declines_unfittable_plan(monkeypatch):
    """Regression: when the flush-load-quantile re-plan would pack more
    live keys into one new shard than its pool holds, the auto policy
    must decline (and re-plan later) — never raise out of a user
    update on the serving path."""
    m = RebalancingShardedMap(
        1, capacity=32, n_buckets=NB, rounds_per_update=1,
        policy=AutoRebalancePolicy(threshold=1.3, min_load=1,
                                   check_every=1))
    ks = np.arange(1, 25, dtype=np.int32)
    m.insert(ks, ks)
    with pytest.raises(ValueError):      # explicit call still raises
        m.start_rebalance((0, NB), capacity=16)
    # drive the policy path into the same wall: the re-plan "moves" a
    # boundary, and the opened map's pool is too small for the content
    import repro.launch.mesh as mesh
    monkeypatch.setattr(mesh, "replan_splits",
                        lambda s, l, threshold: (tuple(s), 9.9))
    calls = {}
    orig = m.start_rebalance

    def tiny_start(splits, **kw):
        calls["hit"] = True
        return orig(splits, capacity=16, **kw)

    monkeypatch.setattr(m, "start_rebalance", tiny_start)
    m.loads[0] = 100                     # past min_load
    ok, _ = m.insert(np.array([1000], np.int32),
                     np.array([1], np.int32))     # must not raise
    assert calls.get("hit")              # the trigger really fired
    assert not m.rebalancing             # ...and was declined
    # the fake skew was cleared (re-plan deferred to fresh load); only
    # the post-decline batch's own flushes remain
    assert int(m.loads.sum()) <= 2
    assert bool(ok[0])


@pytest.mark.slow
def test_multi_shard_subprocess_smoke():
    """Multi-shard coverage for single-device environments: re-run the
    multi-shard tests in a subprocess with 8 forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = ("src" + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else "src")
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         "tests/test_rebalance_live.py",
         "-k", "multi_shard or skew or requestlog",
         "-p", "no:cacheprovider"],      # pytest.ini's -m "not slow"
        capture_output=True, text=True, env=env)   # excludes this test
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "skipped" not in proc.stdout.split("\n")[-2], proc.stdout
