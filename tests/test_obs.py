"""NVTrace observability stack: histogram correctness, span/event
accounting, compile-stall attribution, and the trim-backoff counters.

The histogram tests pin the quantile *bound* the module promises
(``oracle <= quantile(q) <= oracle * growth`` for in-range data) against
a sorted-array oracle, the overflow-bucket contract, and merge
associativity — the property that makes cross-shard snapshot merging
order-independent.  The span tests exercise the innermost-span charging
rule against a real ``StagedIO`` instruction stream and cross-validate
the listener's totals against a ``PersistTrace`` on the same stream via
``FaultsTee``.
"""
import json
import math

import numpy as np
import pytest

from repro.analysis.trace import PersistTrace
from repro.obs.compile import CompileTracker
from repro.obs.metrics import Histogram, MetricsRegistry, log_bounds
from repro.obs.spans import FaultsTee, PersistListener, Tracer
from repro.persistence.manifest import StagedIO
from repro.serving.engine import RequestLog


def _oracle(sorted_vals, q):
    """The exact q-quantile under the histogram's rank convention."""
    n = len(sorted_vals)
    return sorted_vals[min(max(1, math.ceil(q * n)), n) - 1]


# --------------------------------------------------------------------- #
# histogram correctness                                                  #
# --------------------------------------------------------------------- #
def test_log_bounds_cover_and_validate():
    assert log_bounds(1.0, 8.0, 2.0) == (1.0, 2.0, 4.0, 8.0)
    b = log_bounds(0.5, 1e6, 1.25)
    assert b[0] == 0.5 and b[-1] >= 1e6 and b[-2] < 1e6
    for lo, hi, g in ((0.0, 1.0, 2.0), (2.0, 1.0, 2.0), (1.0, 2.0, 1.0)):
        with pytest.raises(ValueError, match="need lo > 0"):
            log_bounds(lo, hi, g)


def test_quantile_bounded_by_oracle_across_buckets():
    """For in-range data the quantile never under-reports and never
    over-reports by more than one bucket ratio — including values that
    land exactly on bucket edges."""
    h = Histogram(lo=1.0, hi=1e4, growth=1.3)
    rng = np.random.default_rng(0)
    vals = np.concatenate([
        rng.uniform(1.0, 1e4, 400),
        np.asarray(h.bounds[:8]),            # exact edges
        np.asarray(h.bounds[:8]) * 1.0001,   # just past the edges
    ])
    for v in vals:
        h.record(float(v))
    s = np.sort(vals)
    for q in (0.01, 0.1, 0.5, 0.9, 0.99, 0.999, 1.0):
        oracle = _oracle(s, q)
        assert oracle <= h.quantile(q) <= oracle * h.growth


def test_quantile_overflow_returns_observed_max_and_empty_is_nan():
    h = Histogram(lo=1.0, hi=10.0, growth=2.0)
    assert math.isnan(h.quantile(0.5))
    for v in (5.0, 100.0, 200.0):
        h.record(v)
    assert h.quantile(0.3) == 8.0      # rank 1 -> bucket (4, 8]
    assert h.quantile(0.5) == 200.0    # rank 2: overflow -> observed max
    assert h.quantile(1.0) == 200.0
    assert h.min == 5.0 and h.max == 200.0


def test_merge_is_associative_and_rejects_layout_mismatch():
    rng = np.random.default_rng(1)
    chunks = [rng.uniform(0.5, 5e4, 100) for _ in range(3)]

    def hist_of(*datasets):
        h = Histogram(lo=1.0, hi=1e4, growth=1.5)
        for d in datasets:
            for v in d:
                h.record(float(v))
        return h

    parts = [hist_of(c) for c in chunks]
    left = hist_of()                   # (a + b) + c
    left.merge(parts[0]); left.merge(parts[1]); left.merge(parts[2])
    ab = hist_of(); ab.merge(parts[1]); ab.merge(parts[2])
    right = hist_of(); right.merge(parts[0]); right.merge(ab)
    direct = hist_of(*chunks)
    for h in (left, right):
        assert h.counts == direct.counts
        assert h.sum == pytest.approx(direct.sum)
        assert (h.min, h.max) == (direct.min, direct.max)
    with pytest.raises(ValueError, match="different"):
        left.merge(Histogram(lo=1.0, hi=1e4, growth=2.0))


def test_merge_snapshot_order_independent():
    """Cross-shard folding: three shard snapshots merged in any order
    give the same registry state (counters/histograms add, and the
    quantiles of the merged histogram match a direct recording)."""
    rng = np.random.default_rng(2)
    shard_vals = [rng.uniform(1.0, 1e3, 50) for _ in range(3)]
    snaps = []
    for i, vals in enumerate(shard_vals):
        reg = MetricsRegistry()
        reg.counter("ops_total", layer="log").inc(10 * (i + 1))
        h = reg.histogram("lat_us", lo=1.0, hi=1e3, growth=1.25)
        for v in vals:
            h.record(float(v))
        snaps.append(json.loads(json.dumps(reg.snapshot())))
    merged = []
    for order in ((0, 1, 2), (2, 0, 1), (1, 2, 0)):
        reg = MetricsRegistry()
        for i in order:
            reg.merge_snapshot(snaps[i])
        merged.append(reg)
    base = merged[0]
    assert base.counter("ops_total", layer="log").value == 60
    h0 = base.histogram("lat_us", lo=1.0, hi=1e3, growth=1.25)
    assert h0.count == 150
    for reg in merged[1:]:
        h = reg.histogram("lat_us", lo=1.0, hi=1e3, growth=1.25)
        assert h.counts == h0.counts             # exact: integer adds
        assert (h.min, h.max) == (h0.min, h0.max)
        assert h.sum == pytest.approx(h0.sum)    # float adds reassociate
        assert reg.counter("ops_total", layer="log").value == 60
    s = np.sort(np.concatenate(shard_vals))
    for q in (0.5, 0.99):
        assert _oracle(s, q) <= h0.quantile(q) <= _oracle(s, q) * h0.growth


def test_registry_kind_conflict_and_monotone_counter():
    reg = MetricsRegistry()
    reg.counter("x_total").inc()
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")
    with pytest.raises(ValueError, match="monotone"):
        reg.counter("x_total").inc(-1)
    gen = reg.gen
    reg.reset()
    assert reg.gen == gen + 1 and reg.entries() == []


def test_prometheus_export_shape():
    reg = MetricsRegistry()
    reg.counter("ops_total", layer="log").inc(3)
    h = reg.histogram("lat_us", lo=1.0, hi=4.0, growth=2.0)
    for v in (0.5, 3.0, 99.0):
        h.record(v)
    text = reg.to_prometheus()
    assert "# TYPE ops_total counter" in text
    assert '# TYPE lat_us histogram' in text
    assert 'ops_total{layer="log"} 3' in text
    assert 'lat_us_bucket{le="+Inf"} 3' in text
    assert "lat_us_count 3" in text


# --------------------------------------------------------------------- #
# snapshot round-trip (hypothesis when available)                        #
# --------------------------------------------------------------------- #
def _roundtrip(counter_n, gauge_v, hist_vals):
    reg = MetricsRegistry()
    reg.counter("c_total", layer="log").inc(counter_n)
    reg.gauge("g", shard="0").set(gauge_v)
    h = reg.histogram("h_us", lo=1.0, hi=1e5, growth=1.5, phase="commit")
    for v in hist_vals:
        h.record(v)
    snap = json.loads(json.dumps(reg.snapshot()))   # the wire format
    twin = MetricsRegistry.from_snapshot(snap)
    assert twin.snapshot() == reg.snapshot()
    twin.merge_snapshot(snap)                        # self-merge doubles
    assert twin.counter("c_total", layer="log").value == 2 * counter_n
    h2 = twin.histogram("h_us", lo=1.0, hi=1e5, growth=1.5, phase="commit")
    assert h2.count == 2 * len(hist_vals)
    assert twin.gauge("g", shard="0").value == gauge_v


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(0, 10 ** 9),
           st.floats(-1e6, 1e6, allow_nan=False),
           st.lists(st.floats(0.0, 1e9, allow_nan=False,
                              allow_infinity=False), max_size=60))
    def test_snapshot_roundtrip_property(counter_n, gauge_v, hist_vals):
        """snapshot -> JSON text -> from_snapshot is exact for every
        metric kind, including empty and overflow-heavy histograms."""
        _roundtrip(counter_n, gauge_v, hist_vals)

except ImportError:        # hypothesis optional: keep fixed probes
    def test_snapshot_roundtrip_property():
        _roundtrip(7, -3.5, [0.0, 1.0, 17.3, 1e9])
        _roundtrip(0, 0.0, [])


# --------------------------------------------------------------------- #
# spans + persistence-event listener                                     #
# --------------------------------------------------------------------- #
def test_innermost_span_gets_the_instruction_bill(tmp_path):
    """The paper's asymmetry as the tracer reports it: a traversal-style
    span persists nothing, the commit span pays every instruction; a
    nested span takes the bill from its parent while it is innermost."""
    reg = MetricsRegistry()
    tr = Tracer(registry=reg)
    io = StagedIO(tmp_path / "log")
    PersistListener(tracer=tr, registry=reg).attach(io)
    with tr.span("plan"):
        pass                                     # traversal: free
    with tr.span("commit") as commit:
        io.write("a.tmp", b"x")
        with tr.span("flush_fence") as inner:
            io.flush("a.tmp")
            io.fence()
        io.publish("a.tmp", "a")
    assert commit.counts == {"write": 1, "publish": 1}
    assert inner.counts == {"flush": 1, "fence": 1}
    recs = tr.records()
    assert [r["span"] for r in recs] == ["plan", "flush_fence", "commit"]
    assert recs[0]["counts"] == {} and recs[0]["dur_us"] >= 0
    assert [r["depth"] for r in recs] == [0, 1, 0]
    assert tr.totals == {"write": 1, "flush": 1, "fence": 1, "publish": 1}
    assert tr.span_counts == tr.totals           # every event was in-span
    assert reg.counter("persist_events_total", kind="fence").value == 1
    assert reg.histogram("span_us", phase="commit").count == 1


def test_disabled_tracer_is_a_noop(tmp_path):
    reg = MetricsRegistry()
    tr = Tracer(registry=reg, enabled=False)
    with tr.span("commit") as s:
        assert s is None
    assert tr.records() == [] and reg.entries() == []


def test_tracer_survives_registry_reset(tmp_path):
    """The gen-keyed handle caches re-resolve after reset(): post-reset
    spans/events land in the *new* registry entries, not orphans."""
    reg = MetricsRegistry()
    tr = Tracer(registry=reg)
    io = StagedIO(tmp_path / "log")
    PersistListener(tracer=tr, registry=reg).attach(io)
    with tr.span("commit"):
        io.write("a", b"x")
    reg.reset()
    with tr.span("commit"):
        io.write("b", b"y")
    assert reg.histogram("span_us", phase="commit").count == 1
    assert reg.counter("persist_events_total", kind="write").value == 1


def test_faults_tee_cross_validates_listener_against_trace(tmp_path):
    """One instruction stream, two sinks: the listener's totals (and the
    tracer's) must equal the PersistTrace's per-kind event counts."""
    reg = MetricsRegistry()
    tr = Tracer(registry=reg)
    listener = PersistListener(tracer=tr, registry=reg)
    trace = PersistTrace()
    io = StagedIO(tmp_path / "log")
    FaultsTee(trace, listener).attach(io)
    with tr.span("workload"):
        for i in range(5):
            io.write(f"f{i}.tmp", b"v")
            io.flush(f"f{i}.tmp")
        io.fence()
        for i in range(5):
            io.publish(f"f{i}.tmp", f"f{i}")
        io.unlink("f0")
    by_kind = {}
    for ev in trace.events:
        by_kind[ev.kind] = by_kind.get(ev.kind, 0) + 1
    assert by_kind == {"write": 5, "flush": 5, "fence": 1,
                       "publish": 5, "trim": 1}
    assert listener.totals == by_kind
    assert tr.totals == by_kind and tr.span_counts == by_kind
    # the trace side kept its CrashPlan site numbering too
    assert [s.kind for s in trace.sites].count("publish") == 5


# --------------------------------------------------------------------- #
# compile-stall attribution                                              #
# --------------------------------------------------------------------- #
def test_compile_tracker_first_call_per_shape_sig():
    reg = MetricsRegistry()
    trk = CompileTracker(registry=reg)
    calls = []
    fn = trk.instrument("sharded.update", "cfg=(2,128,64)",
                        lambda x: (calls.append(1), x * 2)[1])
    a = np.zeros(3, np.int32)
    assert fn(a) is not None and fn(a) is not None and len(calls) == 2
    assert len(trk.events) == 1                  # warm second call
    fn(np.zeros(4, np.int32))                    # new shape -> new stall
    assert len(trk.events) == 2
    assert all(ev.trigger == "steady" for ev in trk.events)
    with trk.reason("resplit_width_change"):
        with trk.reason("capacity_ladder"):      # innermost reason wins
            fn(np.zeros(5, np.int32))
        fn(np.zeros(6, np.int32))
    st = trk.stats()
    assert st["steady"]["events"] == 2
    assert st["capacity_ladder"]["events"] == 1
    assert st["resplit_width_change"]["events"] == 1
    assert all(v["stall_us"] >= 0 for v in st.values())
    assert reg.counter("compile_events_total", site="sharded.update",
                       trigger="capacity_ladder").value == 1


def test_compile_tracker_first_seen_and_disabled():
    trk = CompileTracker(registry=MetricsRegistry())
    assert trk.first_seen("site", "k") is True
    assert trk.first_seen("site", "k") is False
    trk.enabled = False
    fn = trk.instrument("site2", "k", lambda x: x)
    fn(np.zeros(2))
    assert trk.events == []                      # disabled: no recording
    trk.reset()
    assert trk.first_seen("site", "k") is True   # reset clears the cache


# --------------------------------------------------------------------- #
# trim backoff: retry and heal paths, counted on the registry            #
# --------------------------------------------------------------------- #
def _plant_torn(root):
    root.mkdir(parents=True, exist_ok=True)
    (root / "log_000000.json").write_text('{"7": [1, 2')   # mid-write


def test_trim_backoff_counts_retries_and_gives_up_gracefully(
        tmp_path, monkeypatch):
    """Every failed unlink burns one (jittered) backoff attempt and one
    retry counter; exhausting the budget leaves the record torn without
    failing the restart."""
    root = tmp_path / "log"
    _plant_torn(root)
    monkeypatch.setattr(RequestLog, "_backoff", lambda self, attempt: None)
    monkeypatch.setattr(
        StagedIO, "unlink",
        lambda self, rel: (_ for _ in ()).throw(OSError("busy")))
    reg = MetricsRegistry()
    log = RequestLog(root, registry=reg)
    assert reg.counter("serving_trim_retries_total").value == \
        RequestLog._TRIM_RETRIES
    assert reg.counter("serving_trims_total").value == 0
    assert "log_000000.json" in log._torn        # still pending, not lost
    assert not log.is_committed([7]).any()


def test_trim_backoff_heal_path_recovers_the_record(tmp_path, monkeypatch):
    """A writer that lands the payload during the grace interval heals
    the record: it is folded, counted as a heal, and never trimmed."""
    root = tmp_path / "log"
    _plant_torn(root)

    def finish_write(self, attempt):             # the "slow writer" lands
        (root / "log_000000.json").write_text('{"7": [1, 2, 3]}')

    monkeypatch.setattr(RequestLog, "_backoff", finish_write)
    reg = MetricsRegistry()
    log = RequestLog(root, registry=reg)
    assert reg.counter("serving_trim_heals_total").value == 1
    assert reg.counter("serving_trims_total").value == 0
    assert log.is_committed([7]).all()
    assert log.committed()[7] == [1, 2, 3]
    assert (root / "log_000000.json").exists()


def test_backoff_is_bounded_and_jittered():
    import time as _time
    log_cls = RequestLog
    sleeps = []
    real_sleep = _time.sleep
    try:
        _time.sleep = sleeps.append
        inst = object.__new__(log_cls)           # no __init__: just _rng
        import random
        inst._rng = random.Random(0)
        for k in range(8):
            inst._backoff(k)
    finally:
        _time.sleep = real_sleep
    assert len(sleeps) == 8
    for k, s in enumerate(sleeps):
        cap = min(log_cls._TRIM_BACKOFF_S * (1 << k),
                  log_cls._TRIM_BACKOFF_MAX_S)
        assert cap / 2 <= s <= cap               # jitter in [0.5, 1.0)
    assert max(sleeps) <= log_cls._TRIM_BACKOFF_MAX_S
